"""int8-compressed all-reduce: correctness vs plain mean (subprocess with a
multi-device mesh so devices genuinely disagree)."""

import os
import subprocess
import sys

SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import axis_types_kwargs, set_mesh, shard_map
from repro.parallel.compression import compressed_psum

mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(4), ("data",),
                         **axis_types_kwargs(1))
rng = np.random.default_rng(0)
# per-device distinct values, laid out sharded on a leading axis then summed
vals = rng.standard_normal((4, 300)).astype(np.float32) * 5
x = jnp.asarray(vals)

with set_mesh(mesh):
    # build a device-varying replicated-layout tensor via shard_map
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    out = jax.jit(lambda v: compressed_psum(
        shard_map(lambda t: t[0], mesh,
                  in_specs=P("data", None), out_specs=P(None))(v),
        "data"))(xs)
ref = vals.mean(axis=0)
err = np.abs(np.asarray(out) - ref)
bound = np.abs(vals).max() / 127 / 2 * 1.5 + 1e-6
assert err.max() <= bound * 4, (err.max(), bound)
print("OK", float(err.max()))
"""


def test_compressed_psum_matches_mean():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SNIPPET],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
