"""Checkpointing: roundtrip, atomic commit, retention, async semantics."""

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.launch import mesh as mesh_compat


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros(16)},
        "opt": {"step": jnp.asarray(3), "m": {"w": jnp.ones((8, 16))}},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    state = _state()
    mgr.save(10, state, blocking=True)
    restored, meta = mgr.restore(None, jax.eval_shape(lambda: state))
    assert meta["step"] == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoints_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    state = _state()
    mgr.save(10, state, blocking=True)
    # simulate a crash mid-save at step 20: dir exists, no COMMITTED marker
    fake = tmp_path / "step_0000000020"
    fake.mkdir()
    (fake / "0.npy").write_bytes(b"garbage")
    assert mgr.latest_step() == 10
    restored, meta = mgr.restore(None, jax.eval_shape(lambda: state))
    assert meta["step"] == 10


def test_keep_n_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    steps = sorted(mgr._committed_steps())
    assert steps == [3, 4]


def test_structure_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _state(), blocking=True)
    wrong = {"params": {"w": jnp.zeros((8, 16))}}  # missing leaves
    with pytest.raises(AssertionError):
        mgr.restore(None, jax.eval_shape(lambda: wrong))


def test_async_save_overlaps_then_waits(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = _state()
    mgr.save(5, state)          # non-blocking
    mgr.wait()
    assert mgr.latest_step() == 5


def test_restore_with_target_shardings(tmp_path):
    """Mesh-agnostic restore: device_put onto explicit shardings."""
    mgr = CheckpointManager(tmp_path)
    state = _state()
    mgr.save(7, state, blocking=True)
    mesh = mesh_compat.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh,
                                             jax.sharding.PartitionSpec()),
        state)
    restored, _ = mgr.restore(None, jax.eval_shape(lambda: state), sh)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == {"data": 1}
