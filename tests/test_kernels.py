"""Per-kernel interpret-mode validation against the pure-jnp oracles,
swept over shapes and dtypes (per the deliverable-(c) requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.core.tiling import Tile
from repro.kernels.attention import mha_attention
from repro.kernels.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.spmv import pack_csr, spmv
from repro.kernels.spmv.ref import spmv_ell_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [
    (128, 128, 128), (64, 64, 64), (130, 70, 50), (256, 384, 512),
    (8, 8, 8), (1, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_matches_oracle(m, n, k, dtype):
    a = jax.random.normal(KEY, (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = matmul(a, b, tile=Tile(64, 64, 64), interpret=True)
    ref = matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("tile", [Tile(32, 32, 32), Tile(64, 32, 96),
                                  Tile(16, 64, 32)])
def test_matmul_kernel_tile_sweep(tile):
    a = jax.random.normal(KEY, (96, 96), jnp.float32)
    b = jax.random.normal(KEY, (96, 96), jnp.float32)
    out = matmul(a, b, tile=tile, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# spmv
# ---------------------------------------------------------------------------

def _random_csr(rng, m, n, density):
    dense = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    nnz_per_row = (dense != 0).sum(1)
    indptr = np.concatenate([[0], np.cumsum(nnz_per_row)]).astype(np.int32)
    cols = (np.concatenate([np.nonzero(r)[0] for r in dense])
            .astype(np.int32) if nnz_per_row.sum() else
            np.zeros(0, np.int32))
    vals = dense[dense != 0].astype(np.float32)
    return dense, indptr, cols, vals


@pytest.mark.parametrize("m,n,density", [
    (555, 300, 0.02),     # Maragal_2-like skew
    (91, 91, 0.5),        # BIBD-like dense-ish
    (2030, 128, 0.05),    # LD_pilot87-like rows
])
@pytest.mark.parametrize("scheme", ["round_robin", "lpt", "none"])
def test_spmv_kernel_matches_dense(m, n, density, scheme):
    rng = np.random.default_rng(m + n)
    dense, indptr, cols, vals = _random_csr(rng, m, n, density)
    x = rng.standard_normal(n).astype(np.float32)
    mat = pack_csr(indptr, cols, vals, (m, n), scheme=scheme)
    y = spmv(mat, jnp.asarray(x), interpret=True)
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(10, 300),
       n=st.integers(10, 300))
def test_spmv_property_random(seed, m, n):
    rng = np.random.default_rng(seed)
    dense, indptr, cols, vals = _random_csr(rng, m, n, 0.1)
    x = rng.standard_normal(n).astype(np.float32)
    mat = pack_csr(indptr, cols, vals, (m, n))
    y = spmv(mat, jnp.asarray(x), use_kernel=False)  # oracle path
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4, atol=1e-4)
    assert mat.padding_waste >= 1.0


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,sk,hq,hkv,dh,causal,window", [
    (2, 256, 256, 4, 2, 64, True, None),
    (1, 512, 512, 2, 2, 32, True, 128),
    (2, 128, 128, 4, 1, 64, False, None),
    (1, 256, 256, 8, 8, 128, True, None),
])
def test_flash_attention_matches_oracle(b, sq, sk, hq, hkv, dh, causal,
                                        window):
    q = jax.random.normal(KEY, (b, sq, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, hkv, dh),
                          jnp.float32)
    out = mha_attention(q, k, v, causal=causal, window=window,
                        block_q=128, block_k=128, interpret=True)
    ref = mha_attention(q, k, v, causal=causal, window=window,
                        use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    q = jax.random.normal(KEY, (1, 256, 4, 64), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64), dtype)
    out = mha_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    ref = mha_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), use_kernel=False)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)
