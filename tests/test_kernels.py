"""Per-kernel interpret-mode validation against the pure-jnp oracles,
swept over shapes and dtypes (per the deliverable-(c) requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.core import cost_model
from repro.core.tiling import Tile
from repro.kernels.attention import decode_ref, gqa_decode_attention, \
    mha_attention
from repro.kernels.attention import kernel as attn_kernel
from repro.kernels.attention.ref import attention_ref
from repro.kernels.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.spmv import pack_csr, spmv
from repro.kernels.spmv.ref import spmv_ell_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [
    (128, 128, 128), (64, 64, 64), (130, 70, 50), (256, 384, 512),
    (8, 8, 8), (1, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_matches_oracle(m, n, k, dtype):
    a = jax.random.normal(KEY, (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = matmul(a, b, tile=Tile(64, 64, 64), interpret=True)
    ref = matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("tile", [Tile(32, 32, 32), Tile(64, 32, 96),
                                  Tile(16, 64, 32)])
def test_matmul_kernel_tile_sweep(tile):
    a = jax.random.normal(KEY, (96, 96), jnp.float32)
    b = jax.random.normal(KEY, (96, 96), jnp.float32)
    out = matmul(a, b, tile=tile, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# spmv
# ---------------------------------------------------------------------------

def _random_csr(rng, m, n, density):
    dense = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    nnz_per_row = (dense != 0).sum(1)
    indptr = np.concatenate([[0], np.cumsum(nnz_per_row)]).astype(np.int32)
    cols = (np.concatenate([np.nonzero(r)[0] for r in dense])
            .astype(np.int32) if nnz_per_row.sum() else
            np.zeros(0, np.int32))
    vals = dense[dense != 0].astype(np.float32)
    return dense, indptr, cols, vals


@pytest.mark.parametrize("m,n,density", [
    (555, 300, 0.02),     # Maragal_2-like skew
    (91, 91, 0.5),        # BIBD-like dense-ish
    (2030, 128, 0.05),    # LD_pilot87-like rows
])
@pytest.mark.parametrize("scheme", ["round_robin", "lpt", "none"])
def test_spmv_kernel_matches_dense(m, n, density, scheme):
    rng = np.random.default_rng(m + n)
    dense, indptr, cols, vals = _random_csr(rng, m, n, density)
    x = rng.standard_normal(n).astype(np.float32)
    mat = pack_csr(indptr, cols, vals, (m, n), scheme=scheme)
    y = spmv(mat, jnp.asarray(x), interpret=True)
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(10, 300),
       n=st.integers(10, 300))
def test_spmv_property_random(seed, m, n):
    rng = np.random.default_rng(seed)
    dense, indptr, cols, vals = _random_csr(rng, m, n, 0.1)
    x = rng.standard_normal(n).astype(np.float32)
    mat = pack_csr(indptr, cols, vals, (m, n))
    y = spmv(mat, jnp.asarray(x), use_kernel=False)  # oracle path
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4, atol=1e-4)
    assert mat.padding_waste >= 1.0


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,sk,hq,hkv,dh,causal,window", [
    (2, 256, 256, 4, 2, 64, True, None),
    (1, 512, 512, 2, 2, 32, True, 128),
    (2, 128, 128, 4, 1, 64, False, None),
    (1, 256, 256, 8, 8, 128, True, None),
])
def test_flash_attention_matches_oracle(b, sq, sk, hq, hkv, dh, causal,
                                        window):
    q = jax.random.normal(KEY, (b, sq, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, hkv, dh),
                          jnp.float32)
    out = mha_attention(q, k, v, causal=causal, window=window,
                        block_q=128, block_k=128, interpret=True)
    ref = mha_attention(q, k, v, causal=causal, window=window,
                        use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    q = jax.random.normal(KEY, (1, 256, 4, 64), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64), dtype)
    out = mha_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    ref = mha_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), use_kernel=False)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# block-skipping flash attention
# ---------------------------------------------------------------------------

def _flash_vs_ref(bh, sq, sk, dh, causal, window, bq, bk, skip=True,
                  tol=2e-3):
    q = jax.random.normal(KEY, (bh, sq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (bh, sk, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (bh, sk, dh), jnp.float32)
    scale = 1.0 / (dh ** 0.5)
    out = attn_kernel.flash_attention(q, k, v, scale=scale, causal=causal,
                                      window=window, block_q=bq, block_k=bk,
                                      interpret=True, block_skipping=skip)
    ref = attention_ref(q, k, v, scale=scale, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("causal,window", [
    (True, None), (True, 96), (False, None), (False, 96),
])
@pytest.mark.parametrize("bq,bk", [(128, 128), (128, 64), (64, 128)])
def test_block_skip_matches_dense_reference(causal, window, bq, bk):
    """The skipping kernel must be bit-for-purpose identical to the dense
    oracle across the mask grid — skipped blocks are exactly the fully
    masked ones."""
    _flash_vs_ref(2, 256, 256, 32, causal, window, bq, bk, skip=True)


@pytest.mark.parametrize("sq,sk", [
    (300, 300),      # ragged both, sq == sk (ragged prefill)
    (769, 769),      # the old divisibility-assert crash case
    (200, 456),      # sq != sk, both ragged
    (64, 320),       # aligned q, ragged-k tail masked
])
def test_flash_attention_ragged_lengths(sq, sk):
    """Tuned plans must apply to ragged prefill lengths: the q range is
    padded (tail rows sliced off) and the K/V tail masked, instead of the
    old hard `sq % block_q == 0` assert."""
    _flash_vs_ref(1, sq, sk, 32, True, None, 128, 128)
    _flash_vs_ref(1, sq, sk, 32, True, 96, 128, 128)


def test_flash_attention_ragged_gqa_through_wrapper():
    """GQA fold + ragged sq through the public mha_attention wrapper."""
    q = jax.random.normal(KEY, (2, 300, 4, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 300, 2, 32), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 300, 2, 32), jnp.float32)
    out = mha_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    ref = mha_attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_fully_masked_rows_output_zero():
    """Pinned degenerate-row convention: a q row with zero surviving keys
    (reachable at sq > sk with a window) outputs 0 in both the kernel
    (skip and dense paths) and the oracle — not the uniform-softmax mean
    a raw softmax over -1e30 logits would yield."""
    bh, sq, sk, dh = 1, 456, 200, 32
    q = jax.random.normal(KEY, (bh, sq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (bh, sk, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (bh, sk, dh), jnp.float32)
    kw = dict(scale=0.2, causal=True, window=64, block_q=128, block_k=128,
              interpret=True)
    ref = attention_ref(q, k, v, scale=0.2, causal=True, window=64)
    # rows >= sk + window - 1 see no key at all
    assert np.abs(np.asarray(ref[:, sk + 63:])).max() == 0.0
    for skip in (True, False):
        out = attn_kernel.flash_attention(q, k, v, block_skipping=skip, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)


def test_skip_and_dense_paths_agree():
    """block_skipping only removes fully-masked work: both paths must
    produce the same numbers, not just the same oracle distance."""
    q = jax.random.normal(KEY, (1, 256, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 32), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 32), jnp.float32)
    kw = dict(scale=0.17, causal=True, block_q=64, block_k=64,
              interpret=True)
    a = attn_kernel.flash_attention(q, k, v, block_skipping=True, **kw)
    b = attn_kernel.flash_attention(q, k, v, block_skipping=False, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_active_block_pairs_match_mask():
    """The block-level skip law must agree with a brute-force scan of the
    element mask: a block pair is active iff any element survives."""
    for causal, window in [(True, None), (True, 50), (False, 70)]:
        sq = sk = 256
        bq, bk = 64, 32
        q_pos = np.arange(sq)[:, None]
        k_pos = np.arange(sk)[None, :]
        ok = np.ones((sq, sk), bool)
        if causal:
            ok &= q_pos >= k_pos
        if window is not None:
            ok &= (q_pos - k_pos) < window
        brute = 0
        for i in range(sq // bq):
            for j in range(sk // bk):
                brute += ok[i * bq:(i + 1) * bq, j * bk:(j + 1) * bk].any()
        active, total = cost_model.attention_active_block_pairs(
            sq, sk, bq, bk, causal=causal, window=window)
        assert total == (sq // bq) * (sk // bk)
        assert active == brute


def test_causal_skip_halves_counted_k_steps():
    """The measurable tentpole claim, in counted K-steps: causal prefill at
    sq=sk runs the block triangle — >= 1.5x fewer (q, k) block pairs than
    the dense grid for >= 3 q-blocks, ~2x asymptotically."""
    active, total = cost_model.attention_active_block_pairs(
        4096, 4096, 512, 512, causal=True)
    n = 4096 // 512
    assert active == n * (n + 1) // 2
    assert total / active >= 1.5


# ---------------------------------------------------------------------------
# fused decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,dh,cache_len,length,block_k", [
    (2, 4, 2, 64, 256, 256, 128),    # full cache, GQA
    (2, 4, 2, 64, 256, 100, 128),    # partial prefix
    (1, 8, 1, 32, 300, 123, 128),    # cache_len % block_k != 0
    (1, 8, 8, 32, 200, 77, 512),     # block_k > cache_len, MHA
    (1, 2, 2, 32, 96, 1, 64),        # single valid slot
])
def test_decode_kernel_matches_reference(b, hq, hkv, dh, cache_len, length,
                                         block_k):
    q = jax.random.normal(KEY, (b, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, cache_len, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, cache_len, hkv, dh),
                          jnp.float32)
    out = gqa_decode_attention(q, k, v, length=length, block_k=block_k,
                               interpret=True)
    ref = decode_ref(q, k, v, length=length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_decode_kernel_traced_length_under_jit():
    """The serving path passes `index + 1` as a traced scalar; the kernel's
    scalar-prefetch skip must work inside jit with a runtime length."""
    b, hq, hkv, dh, cache_len = 2, 4, 2, 32, 256
    q = jax.random.normal(KEY, (b, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, cache_len, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, cache_len, hkv, dh),
                          jnp.float32)
    f = jax.jit(lambda n: gqa_decode_attention(q, k, v, length=n,
                                               block_k=128, interpret=True))
    for n in (1, 100, 256):
        np.testing.assert_allclose(
            np.asarray(f(jnp.int32(n))),
            np.asarray(decode_ref(q, k, v, length=n)),
            rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("lengths,block_k", [
    ((256, 100), 128),               # mixed depths, one per sequence
    ((123, 1), 128),                 # ragged vs single valid slot
    ((300, 77, 150), 512),           # cache_len % block_k != 0, coarse block
])
def test_decode_kernel_per_row_lengths(lengths, block_k):
    """Continuous batching: every sequence sits at its own cache depth, so
    `length` is a per-sequence vector and each folded row skips its own
    tail blocks.  Must agree with the oracle at every row."""
    b, hq, hkv, dh = len(lengths), 4, 2, 32
    cache_len = 320
    q = jax.random.normal(KEY, (b, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, cache_len, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, cache_len, hkv, dh),
                          jnp.float32)
    lv = jnp.asarray(lengths, jnp.int32)
    out = gqa_decode_attention(q, k, v, length=lv, block_k=block_k,
                               interpret=True)
    ref = decode_ref(q, k, v, length=lv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    # every row must equal its scalar-length counterpart (the degenerate
    # case the vector path generalizes)
    for i, n in enumerate(lengths):
        solo = gqa_decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                    length=int(n), block_k=block_k,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(solo[0]),
                                   rtol=2e-3, atol=2e-3)


def test_decode_kernel_per_row_lengths_traced_under_jit():
    """The continuous-batching serve step carries per-slot write indexes as
    a traced vector; the per-row skip must work inside jit."""
    b, hq, hkv, dh, cache_len = 3, 4, 2, 32, 256
    q = jax.random.normal(KEY, (b, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, cache_len, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, cache_len, hkv, dh),
                          jnp.float32)
    f = jax.jit(lambda lv: gqa_decode_attention(q, k, v, length=lv,
                                                block_k=128, interpret=True))
    for lens in ((1, 100, 256), (256, 256, 256), (13, 200, 64)):
        lv = jnp.asarray(lens, jnp.int32)
        np.testing.assert_allclose(
            np.asarray(f(lv)),
            np.asarray(decode_ref(q, k, v, length=lv)),
            rtol=2e-3, atol=2e-3)


def test_decode_kernel_empty_slot_outputs_zeros():
    """A length-0 row (idle continuous-batching slot) must output zeros on
    BOTH dispatch paths — the kernel's fully-masked-row path and the
    oracle — never uniform attention onto garbage cache contents."""
    b, hq, hkv, dh, cache_len = 2, 4, 2, 32, 128
    q = jax.random.normal(KEY, (b, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, cache_len, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, cache_len, hkv, dh),
                          jnp.float32)
    lv = jnp.asarray([100, 0], jnp.int32)
    out = gqa_decode_attention(q, k, v, length=lv, block_k=64,
                               interpret=True)
    ref = decode_ref(q, k, v, length=lv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    assert np.all(np.asarray(out[1]) == 0) and np.all(np.asarray(ref[1]) == 0)
    assert np.any(np.asarray(out[0]) != 0)


def test_decode_kernel_rejects_wrong_length_shape():
    b, hq, hkv, dh, cache_len = 2, 4, 2, 32, 128
    q = jax.random.normal(KEY, (b, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, cache_len, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, cache_len, hkv, dh),
                          jnp.float32)
    with pytest.raises(ValueError):
        gqa_decode_attention(q, k, v, length=jnp.ones((b + 1,), jnp.int32),
                             interpret=True)


def test_decode_kernel_mixed_cache_dtype():
    """bf16 activations against an f32 KV cache (the serve default)."""
    b, hq, hkv, dh, cache_len = 1, 4, 2, 32, 128
    q = jax.random.normal(KEY, (b, hq, dh), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, cache_len, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, cache_len, hkv, dh),
                          jnp.float32)
    out = gqa_decode_attention(q, k, v, length=90, block_k=64,
                               interpret=True)
    ref = decode_ref(q.astype(jnp.float32), k, v, length=90)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=3e-2, atol=3e-2)
