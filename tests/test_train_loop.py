"""Integration: the end-to-end trainer learns on synthetic data and resumes
from checkpoints bit-exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticSource
from repro.launch import steps
from repro.models import transformer
from repro.optim import adamw


def _setup(arch="qwen3_14b", steps_total=40, lr=3e-3):
    cfg = configs.get_smoke(arch)
    opt_cfg = adamw.AdamWConfig(peak_lr=lr, warmup_steps=5,
                                total_steps=steps_total)
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      seed=1)
    src = SyntheticSource(dcfg)
    step = jax.jit(steps.make_train_step(cfg, opt_cfg))
    return cfg, state, src, step


def test_loss_decreases_on_synthetic_lm():
    _, state, src, step = _setup(steps_total=60, lr=5e-3)
    losses = []
    for t in range(60):
        batch = {k: jnp.asarray(v) for k, v in src.batch(t, 0, 1).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first * 0.9, (first, last)


def test_resume_is_bit_exact(tmp_path):
    _, state, src, step = _setup(steps_total=20)
    ckpt = CheckpointManager(tmp_path)

    # run 10 steps, checkpoint at 6
    s = state
    for t in range(10):
        batch = {k: jnp.asarray(v) for k, v in src.batch(t, 0, 1).items()}
        s, _ = step(s, batch)
        if t + 1 == 6:
            ckpt.save(6, s, blocking=True)
    final_direct = s

    # restore at 6 and replay 6..9
    abs_state = jax.eval_shape(lambda: state)
    restored, meta = ckpt.restore(None, abs_state)
    assert meta["step"] == 6
    s2 = jax.tree.map(jnp.asarray, restored)
    for t in range(6, 10):
        batch = {k: jnp.asarray(v) for k, v in src.batch(t, 0, 1).items()}
        s2, _ = step(s2, batch)

    for a, b in zip(jax.tree.leaves(final_direct), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["phi3_5_moe_42b", "rwkv6_7b"])
def test_other_families_learn(arch):
    _, state, src, step = _setup(arch=arch, steps_total=30)
    losses = []
    for t in range(30):
        batch = {k: jnp.asarray(v) for k, v in src.batch(t, 0, 1).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
