"""Traffic-shaped load generation + the serving benchmark harness.

The invariants: (1) a seeded trace is byte-reproducible and its arrival
process / length distributions honor their specs; (2) the virtual clock
makes the whole serve-loop measurement deterministic — same seeds, same
outcome trace, same TTFT / per-token latency rows (wall-derived fields
are enumerated in `loadgen.VOLATILE_FIELDS` and stripped before
comparison); (3) TTFT percentiles come from the *lifecycle* clock, so an
overloaded run shows real, nonzero queueing delay (the bug this arc
fixed: injected clocks were read but never advanced); (4) closed-loop
sessions throttle themselves by think time; (5) `select_serving_batch`'s
predicted ordering between batch sizes matches the measured ordering on
the virtual clock — the prediction is falsifiable against traffic."""

import sys
import pathlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import autotune
from repro.launch.serve import Server, serve_loop
from repro.models.config import ModelConfig
from repro.runtime import loadgen
from repro.runtime.lifecycle import Lifecycle

REPO = pathlib.Path(__file__).resolve().parents[1]

MAX_LEN = 24
STEP_S = 1e-3     # virtual decode-step time used by the end-to-end tests


def _cfg(**kw):
    base = dict(name="tiny-load", family="dense", num_layers=2, d_model=32,
                d_ff=64, vocab_size=101, num_heads=4, num_kv_heads=2)
    base.update(kw)
    return ModelConfig(**base)


FIXED5 = {"kind": "fixed", "value": 5}
FIXED6 = {"kind": "fixed", "value": 6}


# ---------------------------------------------------------------------------
# trace generation (pure python)
# ---------------------------------------------------------------------------

def test_make_trace_seed_deterministic():
    kw = dict(n=12, rate_rps=3.0,
              prompt_dist={"kind": "uniform", "lo": 4, "hi": 9},
              gen_dist={"kind": "choice", "values": [2, 4, 8]})
    t1 = loadgen.make_trace(seed=7, **kw)
    t2 = loadgen.make_trace(seed=7, **kw)
    assert [t.record() for t in t1] == [t.record() for t in t2]
    t3 = loadgen.make_trace(seed=8, **kw)
    assert [t.record() for t in t1] != [t.record() for t in t3]


def test_trace_arrivals_and_length_bounds():
    trace = loadgen.make_trace(
        seed=1, n=50, rate_rps=2.0,
        prompt_dist={"kind": "uniform", "lo": 4, "hi": 9},
        gen_dist={"kind": "choice", "values": [2, 4, 8]}, start_s=1.0)
    arr = [t.arrival_s for t in trace]
    assert all(b > a for a, b in zip(arr, arr[1:]))     # Poisson cumsum
    assert arr[0] > 1.0                                 # start offset
    assert all(4 <= t.prompt_len <= 9 for t in trace)
    assert all(t.gen_len in (2, 4, 8) for t in trace)
    burst = loadgen.make_trace(seed=1, n=5, rate_rps=0.0,
                               prompt_dist=FIXED5, gen_dist=FIXED6)
    assert all(t.arrival_s == 0.0 for t in burst)       # rate 0 = all at t0


def test_staggered_lengths_match_serve_dist_model():
    """The staggered kind must reproduce launch/serve.py's slot-depth
    ramp: prompt + (2i+1)*gen // (2n)."""
    rng = np.random.default_rng(0)
    n, base, spread = 8, 16, 12
    got = loadgen.sample_lengths(
        rng, n, {"kind": "staggered", "base": base, "spread": spread})
    assert got == [base + ((2 * i + 1) * spread) // (2 * n)
                   for i in range(n)]


def test_lognormal_lengths_heavy_tailed_and_clamped():
    """The heavy-tail kind: median near `mean`, a long upper tail, every
    draw clamped into [lo, hi] — and deterministic under the seed."""
    dist = {"kind": "lognormal", "mean": 8, "sigma": 0.8, "lo": 2,
            "hi": 64}
    got = loadgen.sample_lengths(np.random.default_rng(5), 500, dist)
    assert all(2 <= x <= 64 for x in got)
    med = sorted(got)[len(got) // 2]
    assert 6 <= med <= 10                    # median ~ exp(log(mean))
    assert max(got) > 3 * med                # the tail is actually heavy
    again = loadgen.sample_lengths(np.random.default_rng(5), 500, dist)
    assert got == again
    # lo defaults to 1 when omitted
    slim = loadgen.sample_lengths(
        np.random.default_rng(5), 200,
        {"kind": "lognormal", "mean": 1, "sigma": 2.0, "hi": 9})
    assert all(1 <= x <= 9 for x in slim)


def test_trace_roundtrip_through_jsonl(tmp_path):
    trace = loadgen.make_trace(
        seed=3, n=6, rate_rps=2.0, prompt_dist=FIXED5, gen_dist=FIXED6,
        think_dist={"kind": "exponential", "mean": 0.5},
        ttft_deadline_s=1.5, deadline_s=9.0)
    path = tmp_path / "trace.jsonl"
    loadgen.save_trace(path, trace)
    assert loadgen.load_trace(path) == trace


def test_load_trace_corrupt_line_fails_loudly(tmp_path):
    """A malformed interior line must raise TraceError naming the file,
    line number, and offending payload — never be skipped silently."""
    trace = loadgen.make_trace(seed=3, n=3, rate_rps=2.0,
                               prompt_dist=FIXED5, gen_dist=FIXED6)
    path = tmp_path / "trace.jsonl"
    loadgen.save_trace(path, trace)
    lines = path.read_text().splitlines()
    lines[1] = '{"rid": 1, "arrival_s": "not-a-number"}'
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(loadgen.TraceError, match=r":2: corrupt trace"):
        loadgen.load_trace(path)


def test_load_trace_partial_final_line_is_distinct(tmp_path):
    """A truncated FINAL line is the producer-killed-mid-write signature
    and gets its own message (regenerate the trace), distinct from
    interior corruption."""
    trace = loadgen.make_trace(seed=3, n=3, rate_rps=2.0,
                               prompt_dist=FIXED5, gen_dist=FIXED6)
    path = tmp_path / "trace.jsonl"
    loadgen.save_trace(path, trace)
    text = path.read_text()
    path.write_text(text + '{"rid": 3, "arrival_')     # no newline
    with pytest.raises(loadgen.TraceError, match="partial final line"):
        loadgen.load_trace(path)


def test_sessions_round_robin_preserves_order():
    trace = loadgen.make_trace(seed=3, n=7, rate_rps=1.0,
                               prompt_dist=FIXED5, gen_dist=FIXED6)
    sessions = loadgen.sessions_from_trace(trace, 3)
    assert [len(s) for s in sessions] == [3, 2, 2]
    assert [t.rid for t in sessions[0]] == [0, 3, 6]
    for s in sessions:
        assert [t.rid for t in s] == sorted(t.rid for t in s)


def test_prompt_tokens_deterministic_per_rid():
    a = loadgen.prompt_tokens(5, 3, 16, vocab_size=101)
    assert a.shape == (16,) and a.dtype == np.int64
    assert (0 <= a).all() and (a < 101).all()
    assert np.array_equal(a, loadgen.prompt_tokens(5, 3, 16, 101))
    assert not np.array_equal(a, loadgen.prompt_tokens(5, 4, 16, 101))
    assert not np.array_equal(a, loadgen.prompt_tokens(6, 3, 16, 101))


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------

def test_virtual_clock_is_step_driven():
    clock = loadgen.VirtualClock(0.002, start_s=1.0)
    assert clock() == 1.0
    clock.on_step(5)
    assert clock() == pytest.approx(1.010)
    # step_for: first step whose reading reaches t (ceil), clamped at 0
    assert clock.step_for(0.5) == 0
    assert clock.step_for(1.0041) == 3
    assert clock.step_for(1.003) == 2
    with pytest.raises(ValueError, match="positive"):
        loadgen.VirtualClock(0.0)


def test_virtual_step_floor():
    """Smoke-sized configs predict sub-µs steps; the clock floors at one
    model-ms so ms-rounded latency rows keep resolution."""
    assert loadgen.virtual_step_us(0.3) == loadgen.MIN_VIRTUAL_STEP_US
    assert loadgen.virtual_step_us(25_000.0) == 25_000.0


def test_strip_volatile_prunes_nested_wall_fields():
    report = {"ttft_ms": {"p50": 1.0},
              "wall": {"wall_s": 9.9},
              "requests": [{"rid": 0, "measured_step_us": 3.3,
                            "step_time_ratio": 1.1, "tokens": 5}],
              "predicted_vs_measured": {"predicted_step_us": 2.0,
                                        "divergence": 1.5}}
    assert loadgen.strip_volatile(report) == {
        "ttft_ms": {"p50": 1.0},
        "requests": [{"rid": 0, "tokens": 5}],
        "predicted_vs_measured": {"predicted_step_us": 2.0}}


def test_trace_source_pump_and_idle_jump():
    clock = loadgen.VirtualClock(1e-3)
    lc = Lifecycle(clock=clock)
    trace = [loadgen.TraceRequest(rid=0, arrival_s=0.0, prompt_len=3,
                                  gen_len=2),
             loadgen.TraceRequest(rid=1, arrival_s=0.0042, prompt_len=3,
                                  gen_len=2)]
    src = loadgen.TraceSource(trace, vocab_size=50, seed=1)
    clock.on_step(0)
    src.pump(lc, 0)
    assert lc.submitted == 1 and not src.exhausted()
    assert src.next_arrival_step(lc, 0) == 5     # ceil(4.2ms / 1ms)
    clock.on_step(5)
    src.pump(lc, 5)
    assert lc.submitted == 2 and src.exhausted()
    assert src.next_arrival_step(lc, 5) is None
    assert src.queue_depth                       # timeline sampled


# ---------------------------------------------------------------------------
# end-to-end on the virtual clock (tiny server)
# ---------------------------------------------------------------------------

def _run_trace(cfg, trace, batch, *, queue_limit=0, step_s=STEP_S):
    clock = loadgen.VirtualClock(step_s)
    lc = Lifecycle(queue_limit=queue_limit, clock=clock)
    source = loadgen.TraceSource(trace, cfg.vocab_size, seed=0)
    server = Server(cfg, batch, MAX_LEN, autotune_kernels=False)
    recorder = loadgen.StepTimeRecorder()
    stats = serve_loop(server, lc, watchdog=recorder, source=source)
    metrics = loadgen.collect_metrics(
        lc, predicted_step_us=step_s * 1e6, step_times=recorder.times,
        queue_depth=source.queue_depth)
    return lc, metrics, stats


def test_overloaded_run_is_deterministic_with_nonzero_ttft():
    """Same seeds => identical outcome trace and latency rows (volatile
    fields stripped); and under overload the TTFT tail is *nonzero* and
    step-quantized — proof the serve loop now advances the injected
    lifecycle clock instead of reading a frozen wall value."""
    cfg = _cfg()
    runs = []
    for _ in range(2):
        trace = loadgen.make_trace(seed=3, n=6, rate_rps=2000.0,
                                   prompt_dist=FIXED5, gen_dist=FIXED6)
        lc, metrics, _ = _run_trace(cfg, trace, batch=2)
        runs.append((lc.outcome_trace(), loadgen.strip_volatile(metrics)))
    assert runs[0] == runs[1]
    trace0, metrics0 = runs[0]
    assert metrics0["conserved"] and metrics0["outcomes"]["completed"] == 6
    # ~2 arrivals per virtual step into 2 slots: a queue must form
    assert metrics0["queue_depth_max"] > 0
    assert metrics0["ttft_ms"]["p99"] > 0
    step_ms = STEP_S * 1e3
    for row in trace0:
        assert row["ttft_ms"] is not None
        assert row["ttft_ms"] == pytest.approx(
            round(row["ttft_ms"] / step_ms) * step_ms, abs=1e-6)
    # per-token latency is on the same clock: one step per token
    assert 0 < metrics0["per_token_ms"]["p99"] <= step_ms
    # wall-derived per-request fields exist but are volatile
    assert any("measured_step_us" in r for r in metrics0["requests"]) is False
    lc2, metrics2, _ = _run_trace(cfg, loadgen.make_trace(
        seed=4, n=6, rate_rps=2000.0, prompt_dist=FIXED5,
        gen_dist=FIXED6), batch=2)
    assert loadgen.strip_volatile(metrics2) != runs[0][1]   # seed matters


def test_queue_limit_backpressure_on_trace():
    cfg = _cfg()
    trace = loadgen.make_trace(seed=3, n=6, rate_rps=5000.0,
                               prompt_dist=FIXED5, gen_dist=FIXED6)
    lc, metrics, _ = _run_trace(cfg, trace, batch=1, queue_limit=2)
    assert metrics["conserved"]
    assert metrics["outcomes"]["rejected"] > 0
    assert (metrics["outcomes"]["completed"]
            + metrics["outcomes"]["rejected"]) == 6


def test_session_source_waits_out_think_time():
    """Closed loop: request i+1 of a session is submitted no earlier than
    request i's terminal time plus its think time."""
    cfg = _cfg()
    think = 5 * STEP_S
    trace = [loadgen.TraceRequest(rid=i, arrival_s=0.0, prompt_len=5,
                                  gen_len=4, think_s=think)
             for i in range(3)]
    clock = loadgen.VirtualClock(STEP_S)
    lc = Lifecycle(clock=clock)
    source = loadgen.SessionSource([trace], cfg.vocab_size, seed=0)
    server = Server(cfg, 2, MAX_LEN, autotune_kernels=False)
    serve_loop(server, lc, source=source)
    assert lc.conserved() and lc.counters()["completed"] == 3
    for i in range(1, 3):
        prev, cur = lc.requests[i - 1], lc.requests[i]
        assert cur.submit_t >= prev.finish_t + think - 1e-9


def test_select_serving_batch_pick_not_dominated(monkeypatch, tmp_path):
    """The closed loop on the batch decision: replay one trace at batch 1
    and 4 — the sweep's predicted throughput ordering must match the
    measured ordering on the virtual clock, and the auto-picked batch
    must be the measured winner."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    cfg = _cfg()
    dist = [8] * 8
    pred, meas = {}, {}
    for batch in (1, 4):
        step_us = autotune.predict_decode_step_us(
            cfg, batch, cache_len=MAX_LEN, kv_dtype=jnp.float32,
            lengths=autotune._quantile_lengths(batch, dist, MAX_LEN))
        pred[batch] = batch * 1e6 / step_us
        trace = loadgen.make_trace(seed=5, n=8, rate_rps=0.0,
                                   prompt_dist=FIXED5, gen_dist=FIXED6)
        _, metrics, _ = _run_trace(cfg, trace, batch, step_s=step_us * 1e-6)
        assert metrics["conserved"]
        meas[batch] = metrics["tok_per_s"]
    assert (pred[4] > pred[1]) == (meas[4] > meas[1])
    decision = autotune.select_serving_batch(
        cfg, cache_len=MAX_LEN, prefill_len=5, kv_dtype=jnp.float32,
        candidates=(1, 4), slot_lengths=dist)
    assert decision["batch"] == max(meas, key=meas.get)


def test_run_mix_deterministic_and_full_row(monkeypatch, tmp_path):
    """The benchmark harness end-to-end: one mix run twice produces
    identical reports modulo VOLATILE_FIELDS, with every gated metric
    block present and the SLOs holding."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks import serving_load
    finally:
        sys.path.pop(0)
    spec = {"kind": "open", "seed": 3, "requests": 5, "smoke_requests": 5,
            "rate_factor": 1.0, "prompt_dist": FIXED6,
            "gen_dist": {"kind": "fixed", "value": 4}, "queue_limit": 0,
            "slo": {"ttft_p99_steps": 40, "per_token_p99_steps": 3,
                    "min_tok_per_step_frac": 0.05}}
    rows = [serving_load.run_mix(_cfg(), "mini", spec, smoke=True, batch=2)
            for _ in range(2)]
    assert loadgen.strip_volatile(rows[0]) == loadgen.strip_volatile(rows[1])
    row = rows[0]
    for field in ("ttft_ms", "per_token_ms", "tok_per_s", "queue_depth",
                  "predicted_vs_measured", "trace", "slo", "requests"):
        assert field in row
    assert row["conserved"] and row["slo_ok"] and not row["slo_violations"]
    assert row["wall"]["wall_s"] > 0        # volatile block still reported
    assert len(row["trace"]) == 5 and len(row["requests"]) == 5
