"""Docs hygiene (same invariants the CI docs job enforces via
tools/check_docs.py): no broken relative links, and the ARCHITECTURE.md
module map covers every src/repro module."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "tools"))

import check_docs  # noqa: E402


def test_no_broken_relative_links():
    assert check_docs.check_links() == []


def test_architecture_map_covers_every_module():
    assert check_docs.check_architecture_coverage() == []
