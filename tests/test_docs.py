"""Docs + registry hygiene (same invariants the CI docs job enforces via
tools/check_docs.py and tools/check_registry.py): no broken relative
links, the ARCHITECTURE.md module map covers every src/repro module, and
every registered kernel family has a benchmark row and an equivalence
test."""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402
import check_registry  # noqa: E402


def test_no_broken_relative_links():
    assert check_docs.check_links() == []


def test_architecture_map_covers_every_module():
    assert check_docs.check_architecture_coverage() == []


def test_every_registered_family_is_benchmarked_and_tested():
    assert check_registry.check(REPO / "BENCH_kernels.json") == []


def test_registry_static_parse_matches_runtime_registry():
    """The static parse the CI job relies on must agree with what the
    registry actually loads — else the check could rot silently."""
    static = {f["name"]
              for spec in check_registry.builtin_spec_files()
              for f in check_registry.registered_families(spec)}
    from repro.kernels import registry
    assert static == set(registry.families())
