"""Fault injection end-to-end: the serve loop under the chaos harness.

The invariants: (1) a seeded fault schedule replays bit-for-bit — same
``--fault-seed``, same outcome trace; (2) faults are *absorbed*, not
propagated — a NaN-poisoned slot is quarantined alone while its
neighbours' tokens stay bitwise identical to a fault-free run, an
evicted-then-retried request reproduces solo decode token-for-token
(slot recycling is exact), and a kernel-dispatch failure completes the
step on the jnp reference path with identical tokens; (3) the drain loop
conserves every request and fails loudly (lifecycle table) instead of
spinning when progress is impossible."""

import numpy as np
import pytest

import jax

from repro.launch.serve import Server, serve_loop
from repro.models.config import ModelConfig
from repro.runtime import fault_tolerance, faults
from repro.runtime.lifecycle import Lifecycle, State, submit_all

MAX_LEN = 24


def _cfg(**kw):
    base = dict(name="tiny-chaos", family="dense", num_layers=2, d_model=32,
                d_ff=64, vocab_size=101, num_heads=4, num_kv_heads=2)
    base.update(kw)
    return ModelConfig(**base)


def _requests(cfg, spec):
    """spec: list of (prompt_len, gen_len) -> [(rid, prompt, gen)]."""
    out = []
    for rid, (plen, gen) in enumerate(spec):
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(100 + rid), (plen,), 0,
                               cfg.vocab_size), np.int32)
        out.append((rid, prompt, gen))
    return out


def _run(cfg, batch, reqs, *, plan=None, max_retries=2, max_len=MAX_LEN):
    injector = (faults.FaultInjector(plan, sleep=lambda s: None)
                if plan is not None else None)
    server = Server(cfg, batch, max_len, autotune_kernels=False,
                    injector=injector)
    lc = Lifecycle(max_retries=max_retries, clock=lambda: 0.0)
    submit_all(lc, reqs)
    stats = serve_loop(server, lc)
    return lc, stats, injector


def _tokens(lc):
    return {rid: list(lc.requests[rid].tokens) for rid in lc.requests}


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_fault_plan_is_seed_deterministic():
    p1 = faults.FaultPlan.smoke(7)
    p2 = faults.FaultPlan.smoke(7)
    assert p1.record() == p2.record()
    assert {e.kind for e in p1.events} == set(faults.SMOKE_FAULT_CLASSES)
    assert faults.FaultPlan.smoke(8).record() != p1.record()


def test_same_fault_seed_identical_outcome_trace():
    """The chaos acceptance invariant: the full smoke schedule replayed
    under the same seed produces the same per-request final states, retry
    counts, fired-fault records, and generated tokens."""
    cfg = _cfg()
    spec = [(5, 10), (4, 10), (6, 10), (3, 10), (5, 10), (4, 10)]
    runs = []
    for _ in range(2):
        lc, stats, injector = _run(cfg, 2, _requests(cfg, spec),
                                   plan=faults.FaultPlan.smoke(3))
        # first_new_token_s is wall-clock (volatile by contract, like
        # loadgen's VOLATILE_FIELDS) — everything else must replay exactly
        stats = {k: v for k, v in stats.items() if k != "first_new_token_s"}
        runs.append((lc.outcome_trace(), injector.record(), _tokens(lc),
                     stats))
    assert runs[0] == runs[1]
    trace = runs[0][0]
    assert all(row["state"] in ("completed", "failed") for row in trace)
    # the schedule actually exercised the machinery somewhere
    assert sum(row["retries"] for row in trace) >= 1


# ---------------------------------------------------------------------------
# absorption: quarantine, retry-reproduces-solo, kernel fallback
# ---------------------------------------------------------------------------

def test_nan_quarantine_isolates_the_poisoned_slot():
    """A NaN-logits fault evicts exactly one slot; the neighbour's tokens
    are bitwise identical to the fault-free run, and the retried request —
    restarted from a zeroed slot — reproduces its fault-free tokens too."""
    cfg = _cfg()
    spec = [(5, 8), (7, 8)]                  # requests == batch: no refills
    reqs = _requests(cfg, spec)
    base, _, _ = _run(cfg, 2, reqs)
    plan = faults.FaultPlan([faults.FaultEvent("nan_logits", 3, 0)])
    lc, _, injector = _run(cfg, 2, reqs, plan=plan)
    assert not lc._queue and lc.conserved()
    fired = injector.record()["fired"]
    assert len(fired) == 1 and not fired[0].get("skipped")
    hit_rid = next(r for r in lc.requests.values() if r.retries == 1).rid
    assert lc.counters() == {"completed": 2, "timed_out": 0, "failed": 0,
                             "rejected": 0, "evicted": 1, "retried": 1}
    for rid, prompt, gen in reqs:
        assert _tokens(lc)[rid] == _tokens(base)[rid], (
            f"request {rid} ({'poisoned' if rid == hit_rid else 'neighbour'})"
            f" diverged from the fault-free run")
        assert len(_tokens(lc)[rid]) == gen + 1


def test_kv_corruption_evicted_then_retried_matches_solo():
    """Poisoned *state* (NaN over a slot's KV rows): the guard catches the
    slot on its next step, and the retry — through slot recycling — matches
    the request served alone, token for token."""
    cfg = _cfg()
    spec = [(5, 7), (9, 6), (3, 8)]
    reqs = _requests(cfg, spec)
    plan = faults.FaultPlan([faults.FaultEvent("kv_corrupt", 2, 1)])
    lc, _, _ = _run(cfg, 2, reqs, plan=plan)
    assert lc.counters()["evicted"] == 1 and lc.counters()["completed"] == 3
    retried = next(r for r in lc.requests.values() if r.retries == 1)
    for rid, prompt, gen in reqs:
        solo, _, _ = _run(cfg, 1, [(rid, prompt, gen)])
        assert _tokens(lc)[rid] == _tokens(solo)[rid], (
            f"request {rid} (retried={rid == retried.rid}) diverged "
            f"from solo decode")


def test_evicted_then_retried_matches_solo_fused_kernel(monkeypatch,
                                                        tmp_path):
    """The same retry-reproduces-solo invariant with the decode hot loop
    routed through the fused decode-attention kernel (interpret mode)."""
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "interpret")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    cfg = _cfg()
    spec = [(5, 6), (4, 5)]
    reqs = _requests(cfg, spec)
    plan = faults.FaultPlan([faults.FaultEvent("nan_logits", 2, 0)])
    lc, _, _ = _run(cfg, 2, reqs, plan=plan, max_len=16)
    assert lc.counters()["completed"] == 2
    assert any(r.retries == 1 for r in lc.requests.values())
    for rid, prompt, gen in reqs:
        solo, _, _ = _run(cfg, 1, [(rid, prompt, gen)], max_len=16)
        assert _tokens(lc)[rid] == _tokens(solo)[rid]


def test_kernel_dispatch_fault_falls_back_with_identical_tokens():
    """A kernel-dispatch failure mid-run: the step completes on the jnp
    reference path (no eviction, no retries) and every token matches the
    fault-free run — degradation changes speed, never results."""
    cfg = _cfg()
    spec = [(5, 8), (7, 8)]
    reqs = _requests(cfg, spec)
    base, base_stats, _ = _run(cfg, 2, reqs)
    plan = faults.FaultPlan([faults.FaultEvent("kernel_dispatch", 4, 0)])
    lc, stats, _ = _run(cfg, 2, reqs, plan=plan)
    assert stats["kernel_fallbacks"] == 1
    assert base_stats["kernel_fallbacks"] == 0
    assert lc.counters()["evicted"] == 0
    assert all(r.retries == 0 for r in lc.requests.values())
    assert _tokens(lc) == _tokens(base)


def test_prefill_interrupt_evicts_and_retry_completes():
    """An interrupt between slot reset and cache write: the slot is left
    zeroed, the request is evicted + requeued with backoff, and the retry
    reproduces the fault-free tokens."""
    cfg = _cfg()
    reqs = _requests(cfg, [(6, 5)])
    base, _, _ = _run(cfg, 1, reqs)
    plan = faults.FaultPlan([
        faults.FaultEvent("prefill_interrupt", 0, 0)])   # the 1st prefill
    lc, _, injector = _run(cfg, 1, reqs, plan=plan)
    req = lc.requests[0]
    assert req.retries == 1 and req.state is State.COMPLETED
    assert injector.record()["fired"][0]["kind"] == "prefill_interrupt"
    assert _tokens(lc)[0] == _tokens(base)[0]


def test_fault_with_no_retry_budget_fails_cleanly():
    """max_retries=0: the faulted request ends FAILED (not lost, not
    spinning) and the neighbour still completes."""
    cfg = _cfg()
    spec = [(5, 8), (7, 8)]
    reqs = _requests(cfg, spec)
    plan = faults.FaultPlan([faults.FaultEvent("kv_corrupt", 3, 0)])
    lc, _, _ = _run(cfg, 2, reqs, plan=plan, max_retries=0)
    c = lc.counters()
    assert c["completed"] == 1 and c["failed"] == 1 and c["retried"] == 0
    assert lc.conserved()


# ---------------------------------------------------------------------------
# no-progress guard + watchdog
# ---------------------------------------------------------------------------

def test_stalled_loop_fails_loudly_with_lifecycle_table():
    """A leaked request (non-terminal, not queued, not in a slot) must
    raise with the lifecycle table, not spin forever."""
    cfg = _cfg()
    server = Server(cfg, 1, MAX_LEN, autotune_kernels=False)
    lc = Lifecycle(clock=lambda: 0.0)
    submit_all(lc, _requests(cfg, [(4, 3)]))
    leaked = lc.pop_ready(0)                 # popped but never slotted
    lc.transition(leaked, State.PREFILLING, 0)
    with pytest.raises(RuntimeError, match="request leaked") as exc:
        serve_loop(server, lc)
    assert "prefilling" in str(exc.value)    # the table names the state


def test_undrainable_queue_hits_the_step_ceiling():
    cfg = _cfg()
    server = Server(cfg, 1, MAX_LEN, autotune_kernels=False)
    lc = Lifecycle(clock=lambda: 0.0)
    submit_all(lc, _requests(cfg, [(4, 500)]))   # can't finish in 3 steps
    with pytest.raises(RuntimeError, match="without draining"):
        serve_loop(server, lc, max_steps=3)


def test_backoff_only_queue_jumps_virtual_clock_instead_of_spinning():
    """All queued requests in retry backoff + empty batch: the loop must
    jump to the next eligibility step, so total steps stay near the
    backoff horizon instead of ballooning."""
    cfg = _cfg()
    reqs = _requests(cfg, [(6, 5)])
    plan = faults.FaultPlan([faults.FaultEvent("kv_corrupt", 1, 0)])
    lc, stats, _ = _run(cfg, 1, reqs, plan=plan)
    req = lc.requests[0]
    assert req.retries == 1 and req.state is State.COMPLETED
    # eviction at ~step 1, backoff 4 steps, retry decode of 5 tokens:
    # a spinning loop would show no bound; the jump keeps it tight
    assert stats["steps"] <= 20


def test_decode_watchdog_flags_straggler_and_divergence():
    wd = fault_tolerance.DecodeWatchdog(predicted_us=100.0)
    for step in range(10):
        assert wd.observe(step, 100e-6) is None
    report = wd.observe(10, 250e-6)          # 2.5x the rolling median
    assert report is not None and report.ratio == pytest.approx(2.5)
    s = wd.summary()
    assert s["predicted_step_us"] == 100.0
    assert s["measured_step_us_p50"] == pytest.approx(100.0)
    assert s["divergence"] == pytest.approx(1.0)
    assert len(s["stragglers"]) == 1 and s["stragglers"][0]["step"] == 10
