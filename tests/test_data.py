"""Data pipeline: determinism, sharding, prefetch, memmap source."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.data import (DataConfig, MemmapSource, Prefetcher,
                        SyntheticSource)


def _cfg(**kw):
    base = dict(vocab_size=101, seq_len=16, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000), shards=st.sampled_from([1, 2, 4, 8]))
def test_synthetic_determinism(step, shards):
    cfg = _cfg()
    a = SyntheticSource(cfg).batch(step, 0, shards)
    b = SyntheticSource(cfg).batch(step, 0, shards)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    assert a["tokens"].shape == (cfg.global_batch // shards, cfg.seq_len)


def test_labels_are_next_tokens():
    cfg = _cfg()
    b = SyntheticSource(cfg).batch(0, 0, 1)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_synthetic_has_learnable_structure():
    """Affine recurrence: one (a, c) per seed, stable across steps."""
    cfg = _cfg(global_batch=4, seq_len=256)
    src = SyntheticSource(cfg)
    b0 = src.batch(0, 0, 1)
    b9 = src.batch(9, 0, 1)
    # brute-force the (a, c); the SAME one must explain both steps
    best = (0, None)
    for a in range(2, 8):
        for c in range(1, 101):
            ok = int(((a * b0["tokens"] + c) % 101 == b0["labels"]).mean()
                     * 100)
            if ok > best[0]:
                best = (ok, (a, c))
    assert best[0] > 90
    a, c = best[1]
    assert (((a * b9["tokens"] + c) % 101) == b9["labels"]).mean() > 0.9


def test_frontend_batches():
    cfg = _cfg(frontend="frame", frontend_dim=12)
    b = SyntheticSource(cfg).batch(3, 0, 2)
    assert b["frames"].shape == (4, 16, 12)
    cfg = _cfg(frontend="patch", frontend_dim=12, num_patches=4)
    b = SyntheticSource(cfg).batch(3, 0, 2)
    assert b["patches"].shape == (4, 4, 12)
    assert b["tokens"].shape == (4, 12)
    assert (b["labels"][:, :4] == -1).all()


def test_memmap_source(tmp_path):
    tokens = np.arange(10_000, dtype=np.int32) % 97
    f = tmp_path / "tokens.bin"
    tokens.tofile(f)
    cfg = _cfg(kind="memmap", path=str(f), vocab_size=97)
    src = MemmapSource(cfg)
    a = src.batch(2, 0, 1)
    b = src.batch(2, 0, 1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < 97


def test_prefetcher_orders_steps():
    cfg = _cfg()
    src = SyntheticSource(cfg)
    pf = Prefetcher(src, start_step=10, shard=0, num_shards=1, depth=2)
    try:
        it = iter(pf)
        s0, b0 = next(it)
        s1, b1 = next(it)
        assert (s0, s1) == (10, 11)
        direct = src.batch(10, 0, 1)
        np.testing.assert_array_equal(b0["tokens"], direct["tokens"])
    finally:
        pf.close()
