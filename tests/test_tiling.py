"""Paper claim (eq. 2): the closed-form tile minimizes communication volume.

Property-tested against brute-force integer search over the constrained
space, plus VMEM-budget invariants of the TPU-adapted solver.
"""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.core import tiling
from repro.core.hardware import TPU_V5E


@settings(max_examples=50, deadline=None)
@given(L=st.integers(64, 65536), p=st.integers(1, 64))
def test_eq2_matches_brute_force(L, p):
    """Closed form vs exhaustive search: equal up to integer rounding (the
    rounding gap grows as p*L approaches L^2, i.e. tiny tiles)."""
    n = 4096
    cf = tiling.solve_paper(L, p)
    bf = tiling.brute_force_paper(L, p, n=n)
    q_cf = tiling.comm_volume(n, cf, p)
    q_bf = tiling.comm_volume(n, bf, p)
    assert q_bf <= q_cf <= q_bf * 1.10, (cf, bf)


@settings(max_examples=50, deadline=None)
@given(L=st.integers(64, 65536), p=st.integers(1, 64))
def test_eq2_tile_fits_local_memory(L, p):
    t = tiling.solve_paper(L, p)
    # paper constraint: double-buffered B (2*z*x) + C (x*y) within L
    assert 2 * t.z * t.x + t.x * t.y <= L * 1.05  # int rounding slack


@settings(max_examples=25, deadline=None)
@given(
    vmem=st.sampled_from([2**20, 16 * 2**20, 64 * 2**20, 96 * 2**20]),
    dtype_bytes=st.sampled_from([2, 4]),
)
def test_tpu_tile_respects_vmem_and_alignment(vmem, dtype_bytes):
    t = tiling.solve_tpu(vmem_bytes=vmem, dtype_bytes=dtype_bytes)
    assert t.y % 128 == 0 and t.x % 128 == 0 and t.z % 128 == 0
    used = (t.y * t.z + 2 * t.z * t.x) * dtype_bytes + t.y * t.x * 4
    assert used <= vmem


def test_comm_volume_z_independence():
    """The paper's observation that Q does not depend on z."""
    q1 = tiling.comm_volume(1024, tiling.Tile(32, 16, 1), p=4)
    q2 = tiling.comm_volume(1024, tiling.Tile(32, 16, 64), p=4)
    assert q1 == q2


def test_rect_volume_reduces_to_square():
    t = tiling.Tile(64, 32, 1)
    sq = tiling.comm_volume(2048, t, p=2)
    rect = tiling.comm_volume_rect(2048, 2048, 2048, t, p=2)
    assert math.isclose(sq, rect, rel_tol=1e-12)


def test_bigger_vmem_never_hurts_traffic():
    m = n = k = 8192
    prev = None
    for vmem in (8 * 2**20, 32 * 2**20, TPU_V5E.usable_vmem()):
        t = tiling.solve_tpu(vmem_bytes=vmem, m=m, n=n, k=k)
        q = tiling.comm_volume_rect(m, n, k, t)
        if prev is not None:
            assert q <= prev * 1.01
        prev = q
